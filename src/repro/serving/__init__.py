from repro.serving.engine import Engine, EngineConfig, Request, RequestResult
from repro.serving.evaluate import (EvalResult, evaluate_method,
                                    evaluate_method_batched, make_problems)
from repro.serving.kv_manager import BlockManager
from repro.serving.sampling import SamplingParams, sample_tokens

__all__ = [
    "Engine", "EngineConfig", "Request", "RequestResult",
    "EvalResult", "evaluate_method", "evaluate_method_batched",
    "make_problems",
    "BlockManager", "SamplingParams", "sample_tokens",
]
