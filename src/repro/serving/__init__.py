from repro.serving.engine import (Engine, EngineConfig, Request,
                                  RequestResult, resolve_use_kernel)
from repro.serving.evaluate import (EvalResult, evaluate_method,
                                    evaluate_method_batched, make_problems,
                                    poisson_arrivals)
from repro.serving.faults import (DeviceStepFault, FatalFaultError,
                                  FaultPlan, FaultSpec, FaultStats,
                                  RecoveryConfig)
from repro.serving.kv_manager import BlockManager, Reservation
from repro.serving.metrics import (RequestMetrics, percentiles, summarize,
                                   summarize_by_tenant)
from repro.serving.prefix_cache import CacheStats, PrefixCache
from repro.serving.queue import RequestQueue
from repro.serving.sampling import (SamplingParams, sample_tokens,
                                    sample_tokens_lanes)
from repro.serving.scheduler import (SLO, Arrival, BudgetReplenish,
                                     BurstDone, Cancelled, ChunkDone,
                                     Completion,
                                     DeficitRoundRobin, Event, FIFOPolicy,
                                     SchedulerCore, SchedulingPolicy,
                                     TenantScheduler, TokenBudget,
                                     WeightedTokenBudget, default_scheduler,
                                     parse_tenant_weights)

__all__ = [
    "Engine", "EngineConfig", "Request", "RequestResult",
    "resolve_use_kernel",
    "EvalResult", "evaluate_method", "evaluate_method_batched",
    "make_problems", "poisson_arrivals",
    "BlockManager", "Reservation", "RequestQueue",
    "PrefixCache", "CacheStats",
    "RequestMetrics", "percentiles", "summarize", "summarize_by_tenant",
    "SamplingParams", "sample_tokens", "sample_tokens_lanes",
    "SLO", "SchedulerCore", "SchedulingPolicy", "FIFOPolicy",
    "TenantScheduler", "DeficitRoundRobin", "TokenBudget",
    "WeightedTokenBudget", "default_scheduler", "parse_tenant_weights",
    "Event", "Arrival", "BudgetReplenish", "ChunkDone", "BurstDone",
    "Completion", "Cancelled",
    "FaultPlan", "FaultSpec", "FaultStats", "RecoveryConfig",
    "DeviceStepFault", "FatalFaultError",
]
