from repro.serving.engine import Engine, EngineConfig, RequestResult
from repro.serving.evaluate import EvalResult, evaluate_method, make_problems
from repro.serving.kv_manager import BlockManager
from repro.serving.sampling import SamplingParams, sample_tokens

__all__ = [
    "Engine", "EngineConfig", "RequestResult",
    "EvalResult", "evaluate_method", "make_problems",
    "BlockManager", "SamplingParams", "sample_tokens",
]
