"""qwen3-1.7b [dense] — qk_norm, GQA [hf:Qwen/Qwen3-8B family].

28L d_model=2048 16H (GQA kv=8) d_ff=6144 vocab=151936.
"""
from repro.configs.base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-1.7b",
        arch_type="dense",
        source="hf:Qwen/Qwen3-8B family (Qwen3 tech report arXiv:2505.09388)",
        num_layers=28,
        d_model=2048,
        num_heads=16,
        num_kv_heads=8,
        head_dim=128,
        d_ff=6144,
        vocab_size=151936,
        qk_norm=True,
        rope_theta=1000000.0,
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-1.7b-smoke",
        arch_type="dense",
        source="reduced variant of hf:Qwen/Qwen3-8B",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
        qk_norm=True,
        tie_embeddings=True,
    )
