"""Architecture registry: ``--arch <id>`` resolution for every launcher."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import ModelConfig, SHAPES, ShapeSpec, input_specs, kv_cache_specs

_ARCH_MODULES: Dict[str, str] = {
    "granite-20b": "repro.configs.granite_20b",
    "internvl2-2b": "repro.configs.internvl2_2b",
    "qwen3-1.7b": "repro.configs.qwen3_1_7b",
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    "zamba2-2.7b": "repro.configs.zamba2_2_7b",
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "phi4-mini-3.8b": "repro.configs.phi4_mini_3_8b",
    "mamba2-2.7b": "repro.configs.mamba2_2_7b",
    "starcoder2-3b": "repro.configs.starcoder2_3b",
    "seamless-m4t-large-v2": "repro.configs.seamless_m4t_large_v2",
    # The paper's own evaluation model (extra, beyond the 10 assigned).
    "qwen3-4b-thinking": "repro.configs.qwen3_4b_thinking",
}

ASSIGNED_ARCHS = tuple(k for k in _ARCH_MODULES if k != "qwen3-4b-thinking")
ALL_ARCHS = tuple(_ARCH_MODULES)


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(_ARCH_MODULES[arch])
    return mod.smoke_config() if smoke else mod.full_config()


def serving_config(arch: str = "qwen3-4b-thinking") -> ModelConfig:
    """Smoke-scale config wired to the synthetic-task tokenizer, used by
    the serving engine benchmarks (the model actually sampled from)."""
    import dataclasses

    from repro.data.tokenizer import get_tokenizer

    cfg = get_config(arch, smoke=True)
    return dataclasses.replace(cfg, vocab_size=get_tokenizer().vocab_size)


def get_shape(name: str) -> ShapeSpec:
    return SHAPES[name]


__all__ = [
    "ASSIGNED_ARCHS",
    "ALL_ARCHS",
    "get_config",
    "serving_config",
    "get_shape",
    "input_specs",
    "kv_cache_specs",
    "SHAPES",
]
