"""qwen3-4b-thinking-2507 — the paper's own primary evaluation model
[arXiv:2505.09388; STEP §5.1].

36L d_model=2560 32H (GQA kv=8) d_ff=9728 vocab=151936, qk_norm.
Included beyond the 10 assigned architectures because it is the model the
paper itself evaluates; the step-scorer input dim (2560) matches Appendix A.
"""
from repro.configs.base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-4b-thinking",
        arch_type="dense",
        source="arXiv:2505.09388 (Qwen3); STEP paper §5.1 primary model",
        num_layers=36,
        d_model=2560,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=9728,
        vocab_size=151936,
        qk_norm=True,
        rope_theta=1000000.0,
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-4b-thinking-smoke",
        arch_type="dense",
        source="reduced variant of the STEP paper's primary model",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
        qk_norm=True,
        tie_embeddings=True,
    )
