"""starcoder2-3b [dense] — GQA, RoPE [arXiv:2402.19173].

30L d_model=3072 24H (GQA kv=2) d_ff=12288 vocab=49152.
"""
from repro.configs.base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-3b",
        arch_type="dense",
        source="arXiv:2402.19173 (StarCoder2)",
        num_layers=30,
        d_model=3072,
        num_heads=24,
        num_kv_heads=2,
        head_dim=128,
        d_ff=12288,
        vocab_size=49152,
        rope_theta=999999.4,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-3b-smoke",
        arch_type="dense",
        source="reduced variant of arXiv:2402.19173",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
    )
