"""deepseek-v2-236b [moe] — MLA kv_lora=512, 2 shared + 160 routed top-6
[arXiv:2405.04434].

60L d_model=5120 128H d_ff(expert)=1536 vocab=102400, MoE 160e top-6.
"""
from repro.configs.base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b",
        arch_type="moe",
        source="arXiv:2405.04434 (DeepSeek-V2)",
        num_layers=60,
        d_model=5120,
        num_heads=128,
        num_kv_heads=128,    # MLA: all heads read the shared latent
        head_dim=128,
        d_ff=1536,
        vocab_size=102400,
        use_mla=True,
        kv_lora_rank=512,
        q_lora_rank=1536,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
        num_experts=160,
        num_experts_per_tok=6,
        num_shared_experts=2,
        moe_d_ff=1536,
        rope_theta=10000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b-smoke",
        arch_type="moe",
        source="reduced variant of arXiv:2405.04434",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=4,
        head_dim=64,
        d_ff=128,
        vocab_size=512,
        use_mla=True,
        kv_lora_rank=64,
        q_lora_rank=96,
        qk_nope_head_dim=64,
        qk_rope_head_dim=32,
        v_head_dim=64,
        num_experts=4,
        num_experts_per_tok=2,
        num_shared_experts=1,
        moe_d_ff=128,
        moe_capacity_factor=8.0,
)
