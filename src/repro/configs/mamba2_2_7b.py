"""mamba2-2.7b [ssm] — SSD (state-space duality), attn-free [arXiv:2405.21060].

64L d_model=2560 d_ff=0 vocab=50280, ssm_state=128.
"""
from repro.configs.base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b",
        arch_type="ssm",
        source="arXiv:2405.21060 (Mamba2 / SSD)",
        num_layers=64,
        d_model=2560,
        num_heads=0,
        num_kv_heads=0,
        head_dim=0,
        d_ff=0,
        vocab_size=50280,
        ssm_state_size=128,
        ssm_head_dim=64,
        ssm_expand=2,
        ssm_conv_width=4,
        ssm_chunk_size=256,
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b-smoke",
        arch_type="ssm",
        source="reduced variant of arXiv:2405.21060",
        num_layers=2,
        d_model=128,
        vocab_size=512,
        ssm_state_size=16,
        ssm_head_dim=32,
        ssm_expand=2,
        ssm_conv_width=4,
        ssm_chunk_size=32,
        tie_embeddings=True,
    )
