"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242].

54L d_model=2560 32H (GQA kv=32) d_ff=10240 vocab=32000, ssm_state=64.

The shared attention block (one set of weights, invoked periodically) is
modeled as an attention layer every ``hybrid_attn_every`` layers; only the
attention layers carry a KV cache, which is what makes the hybrid family
sub-quadratic enough for long_500k.
"""
from repro.configs.base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b",
        arch_type="hybrid",
        source="arXiv:2411.15242 (Zamba2)",
        num_layers=54,
        d_model=2560,
        num_heads=32,
        num_kv_heads=32,
        head_dim=80,
        d_ff=10240,
        vocab_size=32000,
        ssm_state_size=64,
        ssm_head_dim=64,
        ssm_expand=2,
        ssm_conv_width=4,
        ssm_chunk_size=256,
        hybrid_attn_every=6,   # 9 shared-attention invocations over 54 layers
        long_context_window=8192,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b-smoke",
        arch_type="hybrid",
        source="reduced variant of arXiv:2411.15242",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        ssm_state_size=16,
        ssm_head_dim=32,
        ssm_expand=2,
        ssm_conv_width=4,
        ssm_chunk_size=32,
        hybrid_attn_every=2,
    )
