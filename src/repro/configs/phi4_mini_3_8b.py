"""phi4-mini-3.8b [dense] — RoPE SwiGLU GQA [arXiv:2412.08905].

32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064.
"""
from repro.configs.base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="phi4-mini-3.8b",
        arch_type="dense",
        source="arXiv:2412.08905 (Phi-4)",
        num_layers=32,
        d_model=3072,
        num_heads=24,
        num_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=200064,
        rope_theta=10000.0,
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="phi4-mini-3.8b-smoke",
        arch_type="dense",
        source="reduced variant of arXiv:2412.08905",
        num_layers=2,
        d_model=192,
        num_heads=3,
        num_kv_heads=1,
        head_dim=64,
        d_ff=384,
        vocab_size=512,
        tie_embeddings=True,
    )
