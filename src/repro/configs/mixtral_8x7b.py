"""mixtral-8x7b [moe] — 8 experts top-2, SWA [arXiv:2401.04088].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000, MoE 8e top-2.
"""
from repro.configs.base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b",
        arch_type="moe",
        source="arXiv:2401.04088 (Mixtral of Experts)",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,          # kept for reference; experts use moe_d_ff
        vocab_size=32000,
        num_experts=8,
        num_experts_per_tok=2,
        moe_d_ff=14336,
        sliding_window=4096,  # native SWA
        rope_theta=1000000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b-smoke",
        arch_type="moe",
        source="reduced variant of arXiv:2401.04088",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
        num_experts=4,
        num_experts_per_tok=2,
        moe_d_ff=512,
        sliding_window=128,
        moe_capacity_factor=8.0,
)
