"""Configuration system for the repro framework.

Every assigned architecture is described by a single frozen `ModelConfig`.
`full_config()` in each ``configs/<arch>.py`` returns the exact published
configuration; ``smoke_config()`` returns a reduced same-family variant
(<=2 layers, d_model<=512, <=4 experts) used by CPU smoke tests.

Input shapes are global; ``input_specs`` builds jax.ShapeDtypeStruct
stand-ins so the launcher can lower/compile without allocating anything.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """A single architecture description covering all 6 assigned families."""

    name: str
    arch_type: str  # dense | moe | ssm | hybrid | vlm | audio
    source: str  # citation for the config

    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 0

    # --- attention options -------------------------------------------------
    qk_norm: bool = False
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None  # native SWA (e.g. mixtral)
    # Window applied only for the long_500k decode shape (beyond-paper
    # rolling-buffer variant that makes dense archs sub-quadratic).
    long_context_window: Optional[int] = 8192

    # --- MLA (DeepSeek-V2) --------------------------------------------------
    use_mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # --- MoE -----------------------------------------------------------------
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0  # per-expert hidden dim
    # capacity = cf * T * top_k / E. Production configs use 1.25 (tokens may
    # drop, Switch-style); smoke configs use a no-drop factor so the
    # decode==full consistency invariant is exact.
    moe_capacity_factor: float = 1.25

    # --- SSM (Mamba2 / SSD) ---------------------------------------------------
    ssm_state_size: int = 0
    ssm_num_heads: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk_size: int = 256

    # --- hybrid (Zamba2): shared attention block every k mamba layers ---------
    hybrid_attn_every: int = 0  # 0 => not hybrid

    # --- encoder-decoder -------------------------------------------------------
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_seq_len: int = 0  # frontend tokens seen by the encoder

    # --- modality frontends (stubs per assignment carve-out) -------------------
    modality: str = "text"  # text | vision | audio
    num_modality_tokens: int = 0  # prepended embedding tokens (vlm)

    # --- numerics / serving -----------------------------------------------------
    dtype: str = "bfloat16"
    tie_embeddings: bool = False
    kv_block_size: int = 16  # paged KV block size (tokens)
    norm_eps: float = 1e-6

    # ------------------------------------------------------------------
    @property
    def d_inner(self) -> int:
        """Mamba2 inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        if self.ssm_num_heads:
            return self.ssm_num_heads
        return self.d_inner // self.ssm_head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.arch_type == "ssm"

    @property
    def uses_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def kv_cache_dims_per_token(self) -> int:
        """Per-layer per-token cache scalar count (drives block bytes)."""
        if self.use_mla:
            # MLA caches the compressed latent + decoupled rope key.
            return self.kv_lora_rank + self.qk_rope_head_dim
        if self.arch_type == "ssm":
            return 0
        return 2 * self.num_kv_heads * self.head_dim

    def attention_layer_ids(self) -> Tuple[int, ...]:
        """Indices of layers that carry a KV cache."""
        if self.arch_type == "ssm":
            return ()
        if self.hybrid_attn_every:
            return tuple(
                i for i in range(self.num_layers)
                if (i + 1) % self.hybrid_attn_every == 0
            )
        return tuple(range(self.num_layers))

    def effective_cache_len(self, shape: ShapeSpec) -> int:
        """Sequence length actually held in KV cache for a shape."""
        length = shape.seq_len
        if self.sliding_window is not None:
            length = min(length, self.sliding_window)
        if shape.name == "long_500k" and self.long_context_window is not None:
            length = min(length, self.long_context_window)
        return length

    def supports_shape(self, shape: ShapeSpec) -> bool:
        if shape.name == "long_500k":
            if self.arch_type in ("ssm", "hybrid"):
                return True
            # dense/moe/vlm/audio run long_500k only via the sliding-window
            # variant (see DESIGN.md §long_500k applicability)
            return (self.sliding_window is not None
                    or self.long_context_window is not None)
        return True


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins, no allocation)
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of the step fn.

    train  -> {tokens, labels[, encoder_embeds / modality_embeds]}
    prefill-> {tokens[, ...frontend embeds]}
    decode -> {tokens (1 new), positions, cache pytree, block_tables}
    """
    shape = SHAPES[shape_name]
    b, s = shape.global_batch, shape.seq_len
    specs: dict = {}
    if shape.kind == "train":
        specs["tokens"] = _sds((b, s), jnp.int32)
        specs["labels"] = _sds((b, s), jnp.int32)
    elif shape.kind == "prefill":
        specs["tokens"] = _sds((b, s), jnp.int32)
    else:  # decode
        specs["tokens"] = _sds((b, 1), jnp.int32)
        specs["positions"] = _sds((b,), jnp.int32)
    if cfg.modality == "vision":
        # precomputed ViT/projector patch embeddings (stub frontend)
        n = cfg.num_modality_tokens or 256
        if shape.kind in ("train", "prefill"):
            specs["modality_embeds"] = _sds((b, n, cfg.d_model), jnp.bfloat16)
    if cfg.is_encoder_decoder and shape.kind in ("train", "prefill"):
        # precomputed mel/conv frame embeddings for the encoder (stub);
        # at decode time the encoder output lives in the cross-attn cache.
        enc_len = cfg.encoder_seq_len or 1024
        specs["encoder_embeds"] = _sds((b, enc_len, cfg.d_model), jnp.bfloat16)
    return specs


def kv_cache_specs(cfg: ModelConfig, shape_name: str) -> dict:
    """KV-cache ShapeDtypeStructs for a decode shape — the DISTRIBUTED
    contiguous layout ``serve_decode_step`` consumes.

    Each data shard owns its sequences' caches as a dense per-sequence
    ring buffer (capacity block-quantised); paged block tables are a
    host-side per-shard allocator concern (serving/kv_manager.py), so the
    device-side step sees:
      k/v_cache:  [num_layers_attn, batch, capacity, KVH, head_dim]
      kv_cache:   [num_layers_attn, batch, capacity, kv_lora+rope]  (MLA)
      ssm_state:  [num_ssm_layers, batch, heads, head_dim, state]
      conv_state: [num_ssm_layers, batch, conv_width-1, d_conv_channels]
    """
    shape = SHAPES[shape_name]
    assert shape.kind == "decode"
    b = shape.global_batch
    cache_len = cfg.effective_cache_len(shape)
    bs = cfg.kv_block_size
    capacity = -(-cache_len // bs) * bs  # block-quantised
    specs: dict = {}
    attn_layers = cfg.attention_layer_ids()
    dt = jnp.bfloat16
    if attn_layers:
        la = len(attn_layers)
        if cfg.use_mla:
            kv_dims = cfg.kv_lora_rank + cfg.qk_rope_head_dim
            specs["kv_cache"] = _sds((la, b, capacity, kv_dims), dt)
        else:
            specs["k_cache"] = _sds(
                (la, b, capacity, cfg.num_kv_heads, cfg.head_dim), dt)
            specs["v_cache"] = _sds(
                (la, b, capacity, cfg.num_kv_heads, cfg.head_dim), dt)
    if cfg.arch_type in ("ssm", "hybrid"):
        # hybrid: ALL num_layers are mamba; shared attention blocks are
        # interleaved *between* groups and counted by attention_layer_ids().
        n_ssm = cfg.num_layers
        specs["ssm_state"] = _sds(
            (n_ssm, b, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state_size),
            jnp.float32)
        specs["conv_state"] = _sds(
            (n_ssm, b, cfg.ssm_conv_width - 1,
             cfg.d_inner + 2 * cfg.ssm_state_size),
            dt)
    if cfg.is_encoder_decoder:
        enc_len = cfg.encoder_seq_len or 1024
        # cross-attention K/V computed once at prefill from encoder output
        specs["cross_k"] = _sds(
            (len(attn_layers), b, enc_len, cfg.num_kv_heads, cfg.head_dim), dt)
        specs["cross_v"] = _sds(
            (len(attn_layers), b, enc_len, cfg.num_kv_heads, cfg.head_dim), dt)
    return specs
