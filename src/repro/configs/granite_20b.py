"""granite-20b [dense] — llama-arch code model [arXiv:2405.04324].

52L d_model=6144 48H (MQA kv=1) d_ff=24576 vocab=49152.
"""
from repro.configs.base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="granite-20b",
        arch_type="dense",
        source="arXiv:2405.04324 (Granite Code Models)",
        num_layers=52,
        d_model=6144,
        num_heads=48,
        num_kv_heads=1,
        head_dim=128,
        d_ff=24576,
        vocab_size=49152,
        rope_theta=10000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-20b-smoke",
        arch_type="dense",
        source="reduced variant of arXiv:2405.04324",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=1,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
    )
