"""internvl2-2b [vlm] — InternViT + InternLM2 backbone [arXiv:2404.16821].

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553.

Per the assignment carve-out, the vision frontend (InternViT + projector) is
a STUB: ``input_specs()`` provides precomputed patch embeddings of shape
(batch, 256, d_model); this config implements the InternLM2 language decoder
that consumes them.
"""
from repro.configs.base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-2b",
        arch_type="vlm",
        source="arXiv:2404.16821 (InternVL2); LM backbone InternLM2-1.8B",
        num_layers=24,
        d_model=2048,
        num_heads=16,
        num_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=92553,
        modality="vision",
        num_modality_tokens=256,
        rope_theta=1000000.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-2b-smoke",
        arch_type="vlm",
        source="reduced variant of arXiv:2404.16821",
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
        modality="vision",
        num_modality_tokens=16,
    )
