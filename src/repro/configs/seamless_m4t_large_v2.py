"""seamless-m4t-large-v2 [audio] — enc-dec, multimodal [arXiv:2308.11596].

24L d_model=1024 16H (kv=16) d_ff=8192 vocab=256206.

Per the assignment carve-out, the audio frontend (mel-spectrogram +
conformer conv feature extractor) is a STUB: ``input_specs()`` provides
precomputed frame embeddings (batch, 1024, d_model) consumed by the text
encoder-decoder transformer implemented here.
"""
from repro.configs.base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2",
        arch_type="audio",
        source="arXiv:2308.11596 (SeamlessM4T v2)",
        num_layers=24,            # decoder layers
        num_encoder_layers=24,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        head_dim=64,
        d_ff=8192,
        vocab_size=256206,
        is_encoder_decoder=True,
        encoder_seq_len=1024,
        modality="audio",
        long_context_window=8192,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2-smoke",
        arch_type="audio",
        source="reduced variant of arXiv:2308.11596",
        num_layers=2,
        num_encoder_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        is_encoder_decoder=True,
        encoder_seq_len=32,
        modality="audio",
    )
