"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) every kernel executes with ``interpret=True`` —
the kernel body runs in Python against the same BlockSpec tiling it would
use on TPU. On a real TPU backend ``interpret`` resolves to False and the
kernels compile to Mosaic.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels import flash_attention as _flash
from repro.kernels import paged_attention as _paged
from repro.kernels import ssd_scan as _ssd
from repro.kernels import step_score as _score


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    scale: Optional[float] = None,
                    blk_q: int = _flash.DEFAULT_BLK_Q,
                    blk_k: int = _flash.DEFAULT_BLK_K):
    return _flash.flash_attention(q, k, v, causal=causal, window=window,
                                  scale=scale, blk_q=blk_q, blk_k=blk_k,
                                  interpret=_interpret())


def paged_attention(q, k_pool, v_pool, block_tables, cache_lens, *,
                    scale: float, k_scale=None, v_scale=None):
    return _paged.paged_attention(q, k_pool, v_pool, block_tables,
                                  cache_lens, scale=scale,
                                  k_scale=k_scale, v_scale=v_scale,
                                  interpret=_interpret())


def paged_attention_prefill(q, k_pool, v_pool, block_tables, prefix_lens,
                            num_valid, own_k, own_v, *, scale: float,
                            window: Optional[int] = None,
                            k_scale=None, v_scale=None):
    return _paged.paged_attention_prefill(
        q, k_pool, v_pool, block_tables, prefix_lens, num_valid,
        own_k, own_v, scale=scale, window=window,
        k_scale=k_scale, v_scale=v_scale, interpret=_interpret())


def paged_attention_sharded(mesh, q, k_pool, v_pool, block_tables,
                            cache_lens, *, scale: float,
                            k_scale=None, v_scale=None):
    """Mesh decode: ``shard_map`` over the ("data",) trace batch with the
    pool's "model"-sharded KV heads handled shard-locally. Kernel grid
    cells are independent per (lane, kv head), so each shard runs the
    exact arithmetic of its slice of the single-device grid — the mesh
    call is bit-identical to the unsharded kernel, no collectives.
    Quantized pools add ``k_scale``/``v_scale`` [NB, page, KVH], sharded
    with the pool's KV heads on "model"."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    pool = P(None, None, "model", None)
    in_specs = [P("data", "model", None), pool, pool,
                P("data", None), P("data")]
    operands = [q, k_pool, v_pool, block_tables, cache_lens]
    if k_scale is not None:
        in_specs += [P(None, None, "model"), P(None, None, "model")]
        operands += [k_scale, v_scale]

    def local(q_, kp, vp, bt, lens, *scales):
        ks, vs = scales if scales else (None, None)
        return paged_attention(q_, kp, vp, bt, lens, scale=scale,
                               k_scale=ks, v_scale=vs)

    return shard_map(
        local, mesh=mesh, in_specs=tuple(in_specs),
        out_specs=P("data", "model", None), check_rep=False,
    )(*operands)


def paged_attention_prefill_sharded(mesh, q, k_pool, v_pool, block_tables,
                                    prefix_lens, num_valid, own_k, own_v, *,
                                    scale: float,
                                    window: Optional[int] = None,
                                    k_scale=None, v_scale=None):
    """Mesh chunked prefill. Chunk jobs run one prompt at a time (batch
    1), so only the "model" axis does real work (heads shard-local);
    the batch-1 operands replicate over "data" and every data shard
    computes the same tile. Quantized pools add ``k_scale``/``v_scale``
    [NB, page, KVH] sharded with the KV heads on "model"."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    head = P(None, None, "model", None)
    pool = P(None, None, "model", None)
    in_specs = [head, pool, pool, P(None, None), P(None), P(None),
                head, head]
    operands = [q, k_pool, v_pool, block_tables, prefix_lens, num_valid,
                own_k, own_v]
    if k_scale is not None:
        in_specs += [P(None, None, "model"), P(None, None, "model")]
        operands += [k_scale, v_scale]

    def local(q_, kp, vp, bt, pls, nv, ok, ov, *scales):
        ks, vs = scales if scales else (None, None)
        return paged_attention_prefill(q_, kp, vp, bt, pls, nv, ok, ov,
                                       scale=scale, window=window,
                                       k_scale=ks, v_scale=vs)

    return shard_map(
        local, mesh=mesh, in_specs=tuple(in_specs),
        out_specs=head, check_rep=False,
    )(*operands)


def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 128, head_group: int = 4,
             initial_state=None):
    return _ssd.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk,
                         head_group=head_group,
                         initial_state=initial_state,
                         interpret=_interpret())


def step_score(hidden, w1, b1, w2, b2, *, blk_b: int = _score.DEFAULT_BLK_B):
    return _score.step_score(hidden, w1, b1, w2, b2, blk_b=blk_b,
                             interpret=_interpret())


def step_score_params(hidden, params):
    """Convenience: scorer params dict -> fused kernel call."""
    return step_score(hidden, params["w1"], params["b1"],
                      params["w2"], params["b2"])
