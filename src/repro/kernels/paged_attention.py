"""Paged attention (THE serving hot spots) as one multi-query Pallas kernel.

Queries attend over a sequence's KV blocks, looked up through a block
table — the exact memory layout the STEP pruning policy manages (pruning
a trace returns its blocks to this pool). One kernel body serves both
engine-facing shapes:

  * DECODE (``paged_attention``): one new query token per sequence
    (C = 1), attending over the pooled cache only — the variant the
    fused ``decode_horizon`` scan calls once per iteration;
  * CHUNKED PREFILL (``paged_attention_prefill``): a chunk of C query
    tokens per sequence attends over the pooled prefix (earlier chunks,
    masked to slots strictly before the chunk) PLUS the chunk's own
    exact KV with causal-within-chunk masking, per-token validity (the
    final chunk is right-padded) and optional sliding-window masking —
    replacing the dense ``[B, KVH, G, C, bp*bs + C]`` score tensor the
    jnp fallback materializes per layer.

TPU adaptation of vLLM's GPU PagedAttention:
  * the block table and per-sequence lengths are SCALAR-PREFETCHED
    (SMEM) so the kernel can compute data-dependent block indices before
    the body runs — the TPU-idiomatic replacement for pointer-chasing;
  * K/V pools stay in HBM (``memory_space=ANY``); each grid step loads
    one [page, hd] tile for one kv head via dynamic slicing;
  * grid = (batch, kv_heads, num_pages [+ 1 own-chunk step]); the page
    dimension is the sequential one carrying online-softmax state in
    VMEM scratch. Pages holding no visible slots are skipped
    (``pl.when``), so a chunk near the front of a long pool touches
    only its live prefix — the dense path pays for every slot;
  * GQA: all G = H // KVH query heads of one kv head are processed
    together, flattened with the chunk dim into a [C*G, hd] tile
    (C*G*hd columns feed the MXU at once).

Numerics contract (pinned by tests against the dense path):
  * f32 accumulation throughout (scores, softmax, PV);
  * empty cache (``cache_len == 0`` and no visible own tokens) emits
    ZEROS via the ``safe_l`` guard — the convention the dense paths
    now share (a bare softmax over all -1e30 scores would average
    garbage KV instead).

VMEM working set per step: page*hd (K) + page*hd (V) + C*G*page
(scores) + C*G*hd (acc) floats — a few hundred KB at the serving tile
sizes, far under the 16 MB budget.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax >= 0.5 renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _mq_paged_kernel(*refs, scale: float, page_size: int, num_pages: int,
                     groups: int, window: Optional[int], has_own: bool,
                     has_scales: bool):
    """Shared body. ``refs`` layout (scalar prefetch first):

      decode : bt, lens, q, k_pool, v_pool, [k_scale, v_scale,]
               o, m, l, acc
      prefill: bt, lens, nvalid, q, k_pool, v_pool,
               [k_scale, v_scale,] own_k, own_v, o, m, l, acc

    ``lens[b]`` = number of valid pooled slots. For prefill (no
    wraparound: slot == position) this doubles as the chunk's start
    position, so query c sits at absolute position ``lens[b] + c``.

    ``has_scales`` marks a quantized (int8/fp8) pool: ``k_scale``/
    ``v_scale`` [NB, page, KVH] f32 live in HBM next to the pools
    (``memory_space=ANY``) and each page tile is dequantized right
    inside the online-softmax loop — cast to f32, multiply by its
    per-(slot, kv-head) scale column — the identical math the dense
    fallback applies to its gathered pages.
    """
    refs = list(refs)
    bt_ref, lens_ref = refs[0], refs[1]
    i = 2
    nvalid_ref = None
    if has_own:
        nvalid_ref = refs[i]
        i += 1
    q_ref, k_pool_ref, v_pool_ref = refs[i:i + 3]
    i += 3
    ks_ref = vs_ref = None
    if has_scales:
        ks_ref, vs_ref = refs[i], refs[i + 1]
        i += 2
    if has_own:
        own_k_ref, own_v_ref = refs[i], refs[i + 1]
        i += 2
    o_ref, m_s, l_s, acc_s = refs[i:i + 4]
    b = pl.program_id(0)
    h = pl.program_id(1)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    cache_len = lens_ref[b]

    def online_update(s, mask):
        """Fold one masked [C*G, S_blk] score tile into the softmax state."""
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_s[...]                      # [C*G, 1]
        l_prev = l_s[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        pexp = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        return m_new, alpha * l_prev + jnp.sum(pexp, axis=-1,
                                               keepdims=True), pexp, alpha

    # ---- pooled-prefix pages -------------------------------------------
    page_start = p * page_size
    live = page_start < cache_len
    if has_own:
        live &= p < num_pages
        if window is not None:
            # the loosest query (chunk-local c = 0, position cache_len)
            # sees slots > cache_len - window; pages entirely left of
            # that are dead for every query in the chunk
            live &= page_start + page_size > cache_len - window

    @pl.when(live)
    def _pool_page():
        block_id = bt_ref[b, p]
        k = k_pool_ref[block_id, pl.ds(0, page_size), h, :]
        v = v_pool_ref[block_id, pl.ds(0, page_size), h, :]
        k = k.astype(jnp.float32)              # [page, hd]
        v = v.astype(jnp.float32)
        if has_scales:                         # in-loop dequantization
            k = k * ks_ref[block_id, pl.ds(0, page_size), h][:, None]
            v = v * vs_ref[block_id, pl.ds(0, page_size), h][:, None]
        q = q_ref[0, 0].astype(jnp.float32)    # [C*G, hd]

        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # [C*G, page]
        slot = page_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = slot < cache_len
        if has_own:
            c = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // groups
            mask &= c < nvalid_ref[b]          # padded queries emit zeros
            if window is not None:
                mask &= slot > (cache_len + c - window)
        m_new, l_new, pexp, alpha = online_update(s, mask)
        acc_s[...] = acc_s[...] * alpha + jax.lax.dot_general(
            pexp, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_s[...] = m_new
        l_s[...] = l_new

    # ---- the chunk's own exact KV (final grid step, prefill only) ------
    if has_own:
        @pl.when(p == num_pages)
        def _own_chunk():
            k = own_k_ref[0, 0].astype(jnp.float32)   # [C, hd]
            v = own_v_ref[0, 0].astype(jnp.float32)
            q = q_ref[0, 0].astype(jnp.float32)       # [C*G, hd]
            s = jax.lax.dot_general(
                q, k, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32) * scale  # [C*G, C]
            c = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // groups
            j = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            nv = nvalid_ref[b]
            mask = (j <= c) & (j < nv) & (c < nv)     # causal + no pad
            if window is not None:
                mask &= j > (c - window)
            m_new, l_new, pexp, alpha = online_update(s, mask)
            acc_s[...] = acc_s[...] * alpha + jax.lax.dot_general(
                pexp, v, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            m_s[...] = m_new
            l_s[...] = l_new

    @pl.when(p == num_pages + int(has_own) - 1)
    def _finalize():
        l = l_s[...]
        safe_l = jnp.where(l == 0.0, 1.0, l)   # fully-masked rows -> zeros
        o_ref[0, 0] = (acc_s[...] / safe_l).astype(o_ref.dtype)


def _mq_paged_call(qf, k_pool, v_pool, block_tables, lens, nvalid,
                   own_k, own_v, *, scale, window, interpret,
                   k_scale=None, v_scale=None):
    """Dispatch the shared kernel. qf [B, KVH, C*G, hd] (flattened query
    tile); own_k/own_v [B, KVH, C, hd] or None (decode); k_scale/v_scale
    [NB, page, KVH] f32 or None (full-precision pool)."""
    B, KVH, CG, hd = qf.shape
    page_size = k_pool.shape[1]
    bp = block_tables.shape[1]
    has_own = own_k is not None
    has_scales = k_scale is not None
    C = own_k.shape[2] if has_own else 1
    groups = CG // C

    kernel = functools.partial(
        _mq_paged_kernel, scale=scale, page_size=page_size, num_pages=bp,
        groups=groups, window=window, has_own=has_own,
        has_scales=has_scales)

    in_specs = [
        pl.BlockSpec((1, 1, CG, hd), lambda b, h, p, *_: (b, h, 0, 0)),
        pl.BlockSpec(memory_space=pltpu.ANY),
        pl.BlockSpec(memory_space=pltpu.ANY),
    ]
    operands = [block_tables, lens]
    if has_own:
        operands.append(nvalid)
    num_prefetch = len(operands)
    if has_scales:
        # per-slot dequant scales [NB, page, KVH]: block-addressed like
        # the pools, so they stay in HBM and each grid step dynamically
        # slices its page's scale column alongside the page tile
        in_specs += [pl.BlockSpec(memory_space=pltpu.ANY),
                     pl.BlockSpec(memory_space=pltpu.ANY)]
    if has_own:
        in_specs += [
            pl.BlockSpec((1, 1, C, hd), lambda b, h, p, *_: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, C, hd), lambda b, h, p, *_: (b, h, 0, 0)),
        ]
    operands += [qf, k_pool, v_pool]
    if has_scales:
        operands += [k_scale, v_scale]
    if has_own:
        operands += [own_k, own_v]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=num_prefetch,
        grid=(B, KVH, bp + int(has_own)),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, CG, hd),
                               lambda b, h, p, *_: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((CG, 1), jnp.float32),
            pltpu.VMEM((CG, 1), jnp.float32),
            pltpu.VMEM((CG, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KVH, CG, hd), qf.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*operands)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_attention(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                    block_tables: jax.Array, cache_lens: jax.Array, *,
                    scale: float, interpret: bool = False,
                    k_scale: Optional[jax.Array] = None,
                    v_scale: Optional[jax.Array] = None) -> jax.Array:
    """Decode: q [B, H, hd]; pools [NB, page, KVH, hd]; block_tables
    [B, bp]; cache_lens [B] valid slots. Returns [B, H, hd].

    The C = 1 specialization of the multi-query kernel — what the fused
    ``decode_horizon`` scan invokes once per iteration. ``cache_len == 0``
    rows emit zeros (the engine's dead-slot convention). ``k_scale``/
    ``v_scale`` [NB, page, KVH] dequantize an int8/fp8 pool in-loop."""
    B, H, hd = q.shape
    KVH = k_pool.shape[2]
    G = H // KVH
    # [B, KVH, G, hd]: all G query heads of a kv head form one MXU tile
    qg = q.reshape(B, KVH, G, hd)
    out = _mq_paged_call(qg, k_pool, v_pool, block_tables,
                         cache_lens, None, None, None,
                         scale=scale, window=None, interpret=interpret,
                         k_scale=k_scale, v_scale=v_scale)
    return out.reshape(B, H, hd)


@functools.partial(jax.jit,
                   static_argnames=("scale", "window", "interpret"))
def paged_attention_prefill(q: jax.Array, k_pool: jax.Array,
                            v_pool: jax.Array, block_tables: jax.Array,
                            prefix_lens: jax.Array, num_valid: jax.Array,
                            own_k: jax.Array, own_v: jax.Array, *,
                            scale: float, window: Optional[int] = None,
                            interpret: bool = False,
                            k_scale: Optional[jax.Array] = None,
                            v_scale: Optional[jax.Array] = None
                            ) -> jax.Array:
    """Chunked prefill: q [B, C, H, hd] attends over the pooled prefix
    plus the chunk's own exact (un-roundtripped) KV.

    prefix_lens [B]: pooled tokens strictly before this chunk — also the
    chunk's start position (prefill never wraps: slot == position, which
    the engine gates chunked prefill on). Query c of row b sits at
    absolute position ``prefix_lens[b] + c`` (positions are contiguous
    across the chunk, including right-padding). num_valid [B]: real
    (non-padded) tokens; padded queries emit zeros and padded own-KV
    columns are masked. own_k/own_v [B, C, KVH, hd]. ``k_scale``/
    ``v_scale`` [NB, page, KVH] dequantize an int8/fp8 pool in-loop (the
    chunk's own KV is exact and never scaled). Returns [B, C, H, hd].
    """
    B, C, H, hd = q.shape
    KVH = k_pool.shape[2]
    G = H // KVH
    # [B, KVH, C*G, hd]: chunk tokens x groups of one kv head in one tile
    qf = q.reshape(B, C, KVH, G, hd).transpose(0, 2, 1, 3, 4) \
        .reshape(B, KVH, C * G, hd)
    ok = own_k.transpose(0, 2, 1, 3)  # [B, KVH, C, hd]
    ov = own_v.transpose(0, 2, 1, 3)
    out = _mq_paged_call(qf, k_pool, v_pool, block_tables,
                         prefix_lens, num_valid, ok, ov,
                         scale=scale, window=window, interpret=interpret,
                         k_scale=k_scale, v_scale=v_scale)
    return out.reshape(B, KVH, C, G, hd).transpose(0, 2, 1, 3, 4) \
        .reshape(B, C, H, hd)
