"""Paged decode attention (THE serving hot spot) as a Pallas TPU kernel.

One new query token per sequence attends over that sequence's KV blocks,
looked up through a block table — the exact memory layout the STEP pruning
policy manages (pruning a trace returns its blocks to this pool).

TPU adaptation of vLLM's GPU PagedAttention:
  * the block table and cache lengths are SCALAR-PREFETCHED (SMEM) so the
    kernel can compute data-dependent block indices before the body runs —
    the TPU-idiomatic replacement for GPU pointer-chasing;
  * K/V pools stay in HBM (``memory_space=ANY``); each grid step loads one
    [page, KVH_blk*hd] tile into registers/VMEM via dynamic slicing —
    the analogue of the per-SM page loop in the CUDA kernel;
  * grid = (batch, kv_heads, num_pages); the page dimension is the
    sequential one carrying online-softmax state in VMEM scratch;
  * GQA: all G = H // KVH query heads of one kv head are processed
    together as a [G, hd] tile (G*hd columns feed the MXU at once).

VMEM working set per step: page_size*hd (K) + page_size*hd (V) +
G*page_size (scores) + G*hd (acc) floats — a few hundred KB at
page_size=16..64, far under the 16 MB budget.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax >= 0.5 renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _paged_kernel(block_tables_ref, cache_lens_ref,  # scalar prefetch
                  q_ref, k_pool_ref, v_pool_ref, o_ref,
                  m_scratch, l_scratch, acc_scratch,
                  *, scale: float, page_size: int, num_pages: int):
    b = pl.program_id(0)
    h = pl.program_id(1)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_scratch[...] = jnp.full_like(m_scratch, NEG_INF)
        l_scratch[...] = jnp.zeros_like(l_scratch)
        acc_scratch[...] = jnp.zeros_like(acc_scratch)

    cache_len = cache_lens_ref[b]
    page_start = p * page_size
    # a page is live if any of its slots hold valid tokens
    live = page_start < cache_len

    @pl.when(live)
    def _compute():
        block_id = block_tables_ref[b, p]
        # dynamic-slice one page of K/V for this kv head from HBM
        k = k_pool_ref[block_id, pl.ds(0, page_size), h, :]
        v = v_pool_ref[block_id, pl.ds(0, page_size), h, :]
        k = k.astype(jnp.float32)              # [page, hd]
        v = v.astype(jnp.float32)
        q = q_ref[0, 0].astype(jnp.float32)    # [G, hd]

        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # [G, page]
        slot = page_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = slot < cache_len
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scratch[...]                # [G, 1]
        l_prev = l_scratch[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        pexp = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(pexp, axis=-1, keepdims=True)
        acc = acc_scratch[...] * alpha + jax.lax.dot_general(
            pexp, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scratch[...] = m_new
        l_scratch[...] = l_new
        acc_scratch[...] = acc

    @pl.when(p == num_pages - 1)
    def _finalize():
        l = l_scratch[...]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scratch[...] / safe_l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_attention(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                    block_tables: jax.Array, cache_lens: jax.Array, *,
                    scale: float, interpret: bool = False) -> jax.Array:
    """q [B, H, hd]; pools [NB, page, KVH, hd]; block_tables [B, bp];
    cache_lens [B]. Returns [B, H, hd]."""
    B, H, hd = q.shape
    NB, page_size, KVH, _ = k_pool.shape
    bp = block_tables.shape[1]
    G = H // KVH
    # [B, KVH, G, hd]: all G query heads of a kv head form one MXU tile
    qg = q.reshape(B, KVH, G, hd)

    kernel = functools.partial(
        _paged_kernel, scale=scale, page_size=page_size, num_pages=bp)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KVH, bp),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, p, *_: (b, h, 0, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, p, *_: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KVH, G, hd), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_tables, cache_lens, qg, k_pool, v_pool)
    return out.reshape(B, H, hd)
