"""Flash attention (prefill hot spot) as a Pallas TPU kernel.

Blockwise online-softmax over the KV sequence. The grid is
``(batch*heads, num_q_blocks, num_kv_blocks)``; TPU grids execute
sequentially in row-major order, so the innermost (kv) dimension revisits
the same output block and carries the online-softmax statistics in VMEM
scratch — the standard TPU flash pattern (cf. jax.experimental.pallas.ops
.tpu.flash_attention).

BlockSpec tiling: q/o blocks [1, blk_q, hd], k/v blocks [1, blk_k, hd].
With blk_q = blk_k = 128 and hd <= 128 the working set is well under
16 MB VMEM and all matmul dims are MXU-aligned (multiples of 128).

Causal masking skips fully-masked kv blocks (2x FLOP saving); an optional
sliding window additionally skips blocks left of the window (what makes
``long_500k`` sub-quadratic for dense archs).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax >= 0.5 renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

DEFAULT_BLK_Q = 128
DEFAULT_BLK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scratch, l_scratch,
                  acc_scratch, *, scale: float, causal: bool,
                  window: Optional[int], blk_q: int, blk_k: int,
                  num_kv_blocks: int):
    q_idx = pl.program_id(1)
    kv_idx = pl.program_id(2)

    @pl.when(kv_idx == 0)
    def _init():
        m_scratch[...] = jnp.full_like(m_scratch, NEG_INF)
        l_scratch[...] = jnp.zeros_like(l_scratch)
        acc_scratch[...] = jnp.zeros_like(acc_scratch)

    q_start = q_idx * blk_q
    k_start = kv_idx * blk_k

    # block-level relevance: skip blocks fully above the causal diagonal
    # or fully left of the sliding window
    relevant = jnp.bool_(True)
    if causal:
        relevant &= k_start <= q_start + blk_q - 1
    if window is not None:
        relevant &= (k_start + blk_k - 1) > (q_start - window)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # [blk_q, hd]
        k = k_ref[0].astype(jnp.float32)  # [blk_k, hd]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # [blk_q, blk_k]

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones(s.shape, jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > (q_pos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scratch[...]          # [blk_q, 1]
        l_prev = l_scratch[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)  # [blk_q, blk_k]
        alpha = jnp.exp(m_prev - m_new)               # [blk_q, 1]
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc_scratch[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scratch[...] = m_new
        l_scratch[...] = l_new
        acc_scratch[...] = acc

    @pl.when(kv_idx == num_kv_blocks - 1)
    def _finalize():
        l = l_scratch[...]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, ...] = (acc_scratch[...] / safe_l).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "scale", "blk_q", "blk_k",
                              "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    scale: Optional[float] = None,
                    blk_q: int = DEFAULT_BLK_Q, blk_k: int = DEFAULT_BLK_K,
                    interpret: bool = False) -> jax.Array:
    """q/k/v [B, H, S, hd] (kv heads pre-broadcast). Returns [B, H, S, hd]."""
    B, H, S, hd = q.shape
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    blk_q = min(blk_q, S)
    blk_k = min(blk_k, S)
    assert S % blk_q == 0 and S % blk_k == 0, (S, blk_q, blk_k)
    nq, nk = S // blk_q, S // blk_k

    qf = q.reshape(B * H, S, hd)
    kf = k.reshape(B * H, S, hd)
    vf = v.reshape(B * H, S, hd)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        blk_q=blk_q, blk_k=blk_k, num_kv_blocks=nk)

    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, blk_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, blk_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, blk_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, hd), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, hd)
