"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def mha_ref(q: jax.Array, k: jax.Array, v: jax.Array,
            causal: bool = True, window: Optional[int] = None,
            scale: Optional[float] = None) -> jax.Array:
    """Full attention. q/k/v [B, H, S, hd] (kv heads already broadcast)."""
    B, H, S, hd = q.shape
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    q_pos = jnp.arange(S)[:, None]
    k_pos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > (q_pos - window)
    scores = jnp.where(mask[None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs.astype(v.dtype), v)


def paged_attention_ref(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                        block_tables: jax.Array, cache_lens: jax.Array,
                        scale: float) -> jax.Array:
    """Decode attention over a paged pool.

    q [B, H, hd]; pools [NB, bs, KVH, hd]; block_tables [B, bp];
    cache_lens [B]. Returns [B, H, hd].
    """
    B, H, hd = q.shape
    NB, bs, KVH, _ = k_pool.shape
    bp = block_tables.shape[1]
    k = k_pool[block_tables].reshape(B, bp * bs, KVH, hd)
    v = v_pool[block_tables].reshape(B, bp * bs, KVH, hd)
    G = H // KVH
    qg = q.reshape(B, KVH, G, hd)
    scores = jnp.einsum("bkgh,bskh->bkgs", qg, k,
                        preferred_element_type=jnp.float32) * scale
    valid = jnp.arange(bp * bs)[None, :] < cache_lens[:, None]
    scores = jnp.where(valid[:, None, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    # empty-cache convention (pinned across kernel + dense paths): a row
    # with zero valid slots emits ZEROS, not a softmax over -inf (NaN) or
    # a uniform average over garbage KV
    probs = jnp.where(valid[:, None, None, :], probs, 0.0)
    out = jnp.einsum("bkgs,bskh->bkgh", probs.astype(v.dtype), v)
    return out.reshape(B, H, hd)


def paged_attention_prefill_ref(q: jax.Array, k_pool: jax.Array,
                                v_pool: jax.Array, block_tables: jax.Array,
                                prefix_lens: jax.Array, num_valid: jax.Array,
                                own_k: jax.Array, own_v: jax.Array,
                                scale: float,
                                window: Optional[int] = None) -> jax.Array:
    """Chunked-prefill paged attention oracle (mirrors the dense layer
    math in ``layers.gqa_attention_prefill_chunk``).

    q [B, C, H, hd]; pools [NB, bs, KVH, hd]; block_tables [B, bp];
    prefix_lens [B] pooled tokens before the chunk (== chunk start,
    slot == position); num_valid [B] real tokens in the chunk;
    own_k/own_v [B, C, KVH, hd]. Returns [B, C, H, hd]; padded queries
    and empty rows emit zeros.
    """
    B, C, H, hd = q.shape
    NB, bs, KVH, _ = k_pool.shape
    bp = block_tables.shape[1]
    G = H // KVH
    kc = k_pool[block_tables].reshape(B, bp * bs, KVH, hd)
    vc = v_pool[block_tables].reshape(B, bp * bs, KVH, hd)
    keys = jnp.concatenate([kc, own_k], axis=1)
    vals = jnp.concatenate([vc, own_v], axis=1)
    positions = prefix_lens[:, None] + jnp.arange(C)[None, :]
    valid = jnp.arange(C)[None, :] < num_valid[:, None]
    q_pos = positions[:, :, None]
    pool_pos = jnp.arange(bp * bs)[None, None, :]
    pool_mask = jnp.broadcast_to(pool_pos < prefix_lens[:, None, None],
                                 (B, C, bp * bs))
    own_mask = (positions[:, None, :] <= q_pos) & valid[:, None, :]
    mask = jnp.concatenate(
        [pool_mask, jnp.broadcast_to(own_mask, (B, C, C))], axis=2)
    if window is not None:
        all_pos = jnp.concatenate(
            [jnp.broadcast_to(pool_pos, (B, 1, bp * bs)),
             jnp.broadcast_to(positions[:, None, :], (B, 1, C))], axis=2)
        mask &= all_pos > (q_pos - window)
    mask &= valid[:, :, None]
    qg = q.reshape(B, C, KVH, G, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, keys,
                        preferred_element_type=jnp.float32) * scale
    scores = jnp.where(mask[:, None, None], scores, -1e30)
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.where(mask[:, None, None], jnp.exp(scores - m), 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    p = p / jnp.where(l == 0.0, 1.0, l)
    out = jnp.einsum("bkgqs,bskh->bqkgh", p, vals.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    return out.reshape(B, C, H, hd)


def ssd_scan_ref(x: jax.Array, dt: jax.Array, A: jax.Array,
                 Bm: jax.Array, Cm: jax.Array,
                 initial_state: Optional[jax.Array] = None):
    """Sequential SSD recurrence (the definitionally-correct oracle).

    x [B,S,H,P]; dt [B,S,H]; A [H]; Bm/Cm [B,S,N].
    h_t = h_{t-1} * exp(dt_t A) + dt_t B_t x_t ;  y_t = C_t h_t.
    Returns (y [B,S,H,P], final_state [B,H,P,N]).
    """
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    if initial_state is None:
        initial_state = jnp.zeros((B, H, P, N), jnp.float32)

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp  # [B,H,P], [B,H], [B,N], [B,N]
        decay = jnp.exp(dt_t * A[None, :])  # [B,H]
        dBx = jnp.einsum("bh,bn,bhp->bhpn", dt_t, B_t, x_t)
        h = h * decay[..., None, None] + dBx
        y = jnp.einsum("bhpn,bn->bhp", h, C_t)
        return h, y

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(Bm, 1, 0), jnp.moveaxis(Cm, 1, 0))
    final, ys = jax.lax.scan(step, initial_state, xs)
    return jnp.moveaxis(ys, 0, 1), final


def step_score_ref(hidden: jax.Array, w1: jax.Array, b1: jax.Array,
                   w2: jax.Array, b2: jax.Array) -> jax.Array:
    """Fused 2-layer MLP scorer. hidden [B, D] -> scores [B]."""
    z = jax.nn.relu(hidden.astype(jnp.float32) @ w1 + b1)
    return jax.nn.sigmoid((z @ w2 + b2)[..., 0])
