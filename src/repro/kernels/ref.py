"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def mha_ref(q: jax.Array, k: jax.Array, v: jax.Array,
            causal: bool = True, window: Optional[int] = None,
            scale: Optional[float] = None) -> jax.Array:
    """Full attention. q/k/v [B, H, S, hd] (kv heads already broadcast)."""
    B, H, S, hd = q.shape
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    q_pos = jnp.arange(S)[:, None]
    k_pos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > (q_pos - window)
    scores = jnp.where(mask[None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs.astype(v.dtype), v)


def paged_attention_ref(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                        block_tables: jax.Array, cache_lens: jax.Array,
                        scale: float) -> jax.Array:
    """Decode attention over a paged pool.

    q [B, H, hd]; pools [NB, bs, KVH, hd]; block_tables [B, bp];
    cache_lens [B]. Returns [B, H, hd].
    """
    B, H, hd = q.shape
    NB, bs, KVH, _ = k_pool.shape
    bp = block_tables.shape[1]
    k = k_pool[block_tables].reshape(B, bp * bs, KVH, hd)
    v = v_pool[block_tables].reshape(B, bp * bs, KVH, hd)
    G = H // KVH
    qg = q.reshape(B, KVH, G, hd)
    scores = jnp.einsum("bkgh,bskh->bkgs", qg, k,
                        preferred_element_type=jnp.float32) * scale
    valid = jnp.arange(bp * bs)[None, :] < cache_lens[:, None]
    scores = jnp.where(valid[:, None, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", probs.astype(v.dtype), v)
    return out.reshape(B, H, hd)


def ssd_scan_ref(x: jax.Array, dt: jax.Array, A: jax.Array,
                 Bm: jax.Array, Cm: jax.Array,
                 initial_state: Optional[jax.Array] = None):
    """Sequential SSD recurrence (the definitionally-correct oracle).

    x [B,S,H,P]; dt [B,S,H]; A [H]; Bm/Cm [B,S,N].
    h_t = h_{t-1} * exp(dt_t A) + dt_t B_t x_t ;  y_t = C_t h_t.
    Returns (y [B,S,H,P], final_state [B,H,P,N]).
    """
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    if initial_state is None:
        initial_state = jnp.zeros((B, H, P, N), jnp.float32)

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp  # [B,H,P], [B,H], [B,N], [B,N]
        decay = jnp.exp(dt_t * A[None, :])  # [B,H]
        dBx = jnp.einsum("bh,bn,bhp->bhpn", dt_t, B_t, x_t)
        h = h * decay[..., None, None] + dBx
        y = jnp.einsum("bhpn,bn->bhp", h, C_t)
        return h, y

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(Bm, 1, 0), jnp.moveaxis(Cm, 1, 0))
    final, ys = jax.lax.scan(step, initial_state, xs)
    return jnp.moveaxis(ys, 0, 1), final


def step_score_ref(hidden: jax.Array, w1: jax.Array, b1: jax.Array,
                   w2: jax.Array, b2: jax.Array) -> jax.Array:
    """Fused 2-layer MLP scorer. hidden [B, D] -> scores [B]."""
    z = jax.nn.relu(hidden.astype(jnp.float32) @ w1 + b1)
    return jax.nn.sigmoid((z @ w2 + b2)[..., 0])
