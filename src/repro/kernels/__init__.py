"""Pallas TPU kernels for the serving hot spots the paper optimizes.

  flash_attention         — one-shot prefill attention (blockwise
                            online softmax, SWA)
  paged_attention         — decode attention over the paged KV pool
                            (C=1 face of the multi-query kernel; what
                            the fused decode-horizon scan calls)
  paged_attention_prefill — chunked prefill over pooled prefix + exact
                            own-chunk KV (the multi-query face)
  ssd_scan                — Mamba2 SSD chunked scan (mamba2/zamba2)
  step_score              — fused STEP scorer MLP over decode hiddens

``ops`` also exposes ``paged_attention[_prefill]_sharded`` — the
shard_map routing mesh engines use (lanes on "data", pool KV heads
computed shard-locally on "model").

``ops`` holds the jit'd wrappers (interpret=True on CPU); ``ref`` holds
the pure-jnp oracles the tests assert against.
"""
from repro.kernels import ops, ref  # noqa: F401
