"""Pallas TPU kernels for the serving hot spots the paper optimizes.

  flash_attention — prefill attention (blockwise online softmax, SWA)
  paged_attention — decode attention over the paged KV pool
  ssd_scan        — Mamba2 SSD chunked scan (mamba2/zamba2 archs)
  step_score      — fused STEP scorer MLP over decode-batch hiddens

``ops`` holds the jit'd wrappers (interpret=True on CPU); ``ref`` holds
the pure-jnp oracles the tests assert against.
"""
from repro.kernels import ops, ref  # noqa: F401
