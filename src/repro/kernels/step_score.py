"""Fused STEP scorer (2-layer MLP + sigmoid) as a Pallas TPU kernel.

The scorer runs inside the decode step on every token of the decode batch
(scores are consumed only at "\n\n" boundaries, but the fused evaluation
is branch-free and costs < 1e-6 of a model step — paper Appendix D). Fusing
it into one kernel keeps the hidden states in VMEM: the [B, D] decode-batch
hiddens never round-trip to HBM between the two matmuls.

Tiling: one grid row per batch block; weights [D, 512] + [512, 1] are
small enough (< 6 MB for D = 2560 in fp32) to live fully in VMEM and are
re-fetched per block — hardware-aligned (512 and D multiples of 128; the
batch block is padded to 8).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax >= 0.5 renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

DEFAULT_BLK_B = 128


def _scorer_kernel(h_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    h = h_ref[...].astype(jnp.float32)          # [blk_b, D]
    z = jax.lax.dot_general(
        h, w1_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) + b1_ref[...][None, :]
    z = jnp.maximum(z, 0.0)                     # ReLU
    logit = jax.lax.dot_general(
        z, w2_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) + b2_ref[...][None, :]
    o_ref[...] = jax.nn.sigmoid(logit)          # [blk_b, 1]


@functools.partial(jax.jit, static_argnames=("blk_b", "interpret"))
def step_score(hidden: jax.Array, w1: jax.Array, b1: jax.Array,
               w2: jax.Array, b2: jax.Array, *,
               blk_b: int = DEFAULT_BLK_B,
               interpret: bool = False) -> jax.Array:
    """hidden [B, D] -> correctness scores [B] in [0, 1]."""
    B, D = hidden.shape
    Hd = w1.shape[1]
    blk_b = min(blk_b, B)
    pad = (-B) % blk_b
    h = jnp.pad(hidden, ((0, pad), (0, 0))) if pad else hidden
    nb = h.shape[0] // blk_b

    out = pl.pallas_call(
        _scorer_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((blk_b, D), lambda i: (i, 0)),
            pl.BlockSpec((D, Hd), lambda i: (0, 0)),
            pl.BlockSpec((Hd,), lambda i: (0,)),
            pl.BlockSpec((Hd, 1), lambda i: (0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((blk_b, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((h.shape[0], 1), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(h, w1, b1, w2, b2)
    return out[:B, 0]
