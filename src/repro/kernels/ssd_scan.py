"""Mamba2 SSD (state-space duality) chunked scan as a Pallas TPU kernel.

The SSD insight (Dao & Gu, arXiv:2405.21060) is that the selective-SSM
recurrence equals a semiseparable matmul: split the sequence into chunks,
do the quadratic part within a chunk on the MXU, and carry a [H, P, N]
state across chunks. That maps perfectly onto a TPU Pallas grid:

  grid = (batch*head_groups, num_chunks) — the chunk dimension is the
  sequential ("arbitrary") one; the running state lives in VMEM scratch
  across grid steps, exactly like flash attention's online-softmax stats.

Per chunk (l = chunk len, G = heads per block, P = head dim, N = state):
  1. dA cumsum over the chunk               [G, l]
  2. intra-chunk:  (C B^T ∘ L-decay) dt x   — two [l,l]x[l,·] MXU matmuls
  3. carry-in:     C h_prev (decayed)       — [l,N]x[N,P] matmul
  4. state update: h = h*decay_l + (decay-weighted B)^T (dt x)

VMEM per step: l*(P+2N+G) + G*P*N floats; at l=128, P=64, N=128, G=4
that is ~0.4 MB — comfortably inside 16 MB, MXU dims multiple of 128
where it matters ([l,l] and [l,N] matmuls).

Heads are processed in groups of G per grid row (all sharing Bm/Cm since
ngroups=1 in Mamba2), so the B/C loads amortize across the group.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax >= 0.5 renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams


def _ssd_kernel(x_ref, dt_ref, A_ref, B_ref, C_ref, y_ref, h_ref, h_scratch,
                *, chunk: int, heads: int, num_chunks: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        h_scratch[...] = jnp.zeros_like(h_scratch)

    x = x_ref[0].astype(jnp.float32)     # [l, G, P]
    dt = dt_ref[0].astype(jnp.float32)   # [l, G]
    A = A_ref[0].astype(jnp.float32)     # [G]
    Bm = B_ref[0].astype(jnp.float32)    # [l, N]
    Cm = C_ref[0].astype(jnp.float32)    # [l, N]
    l = x.shape[0]

    dA = dt * A[None, :]                          # [l, G]
    dA_cs = jnp.cumsum(dA, axis=0)                # inclusive cumsum [l, G]

    # C B^T once for all heads in the group: [l, l]
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    row = jax.lax.broadcasted_iota(jnp.int32, (l, l), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (l, l), 1)
    tri = row >= col

    ys = []
    for g in range(heads):  # static unroll over the head group
        cs_g = dA_cs[:, g]                          # [l]
        # L[i,j] = exp(cs_i - cs_j) for j<=i  (segment decay)
        L = jnp.exp(cs_g[:, None] - cs_g[None, :])
        L = jnp.where(tri, L, 0.0)
        scores = cb * L                             # [l, l]
        dtx = dt[:, g:g + 1] * x[:, g, :]           # [l, P]
        y_diag = jax.lax.dot_general(
            scores, dtx, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)     # [l, P]

        # carry-in from previous chunks: y_off = (C * exp(cs)) @ h_prev^T
        h_prev = h_scratch[g]                       # [P, N]
        c_dec = Cm * jnp.exp(cs_g)[:, None]         # [l, N]
        y_off = jax.lax.dot_general(
            c_dec, h_prev, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)     # [l, P]
        ys.append(y_diag + y_off)

        # state update: h_new = h_prev * exp(cs_last)
        #   + sum_j exp(cs_last - cs_j) dt_j x_j B_j^T
        decay_states = jnp.exp(cs_g[-1] - cs_g)     # [l]
        bw = Bm * decay_states[:, None]             # [l, N]
        h_inc = jax.lax.dot_general(
            dtx, bw, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)     # [P, N]
        h_scratch[g] = h_prev * jnp.exp(cs_g[-1]) + h_inc

    y_ref[0, ...] = jnp.stack(ys, axis=1).astype(y_ref.dtype)  # [l, G, P]

    @pl.when(c == num_chunks - 1)
    def _emit_state():
        h_ref[0, ...] = h_scratch[...]


@functools.partial(jax.jit,
                   static_argnames=("chunk", "head_group", "interpret"))
def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array,
             Bm: jax.Array, Cm: jax.Array, *, chunk: int = 128,
             head_group: int = 4,
             initial_state: Optional[jax.Array] = None,
             interpret: bool = False):
    """x [B,S,H,P]; dt [B,S,H]; A [H]; Bm/Cm [B,S,N] (ngroups=1).

    Returns (y [B,S,H,P], final_state [B,H,P,N]).
    ``initial_state`` is unsupported by the kernel path (decode uses the
    single-step recurrence); it must be None.
    """
    assert initial_state is None, "kernel path starts from zero state"
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    G = min(head_group, H)
    while H % G:
        G -= 1
    HG = H // G
    nc = S // chunk

    # regroup heads: [B, S, HG, G, P] -> [B*HG, S, G, P]
    xg = x.reshape(B, S, HG, G, P).transpose(0, 2, 1, 3, 4) \
        .reshape(B * HG, S, G, P)
    dtg = dt.reshape(B, S, HG, G).transpose(0, 2, 1, 3).reshape(B * HG, S, G)
    Ag = jnp.broadcast_to(A.reshape(HG, G)[None], (B, HG, G)) \
        .reshape(B * HG, G)
    Bg = jnp.broadcast_to(Bm[:, None], (B, HG, S, N)).reshape(B * HG, S, N)
    Cg = jnp.broadcast_to(Cm[:, None], (B, HG, S, N)).reshape(B * HG, S, N)

    kernel = functools.partial(_ssd_kernel, chunk=chunk, heads=G,
                               num_chunks=nc)

    y, h_final = pl.pallas_call(
        kernel,
        grid=(B * HG, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, G, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, chunk, G), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, G), lambda b, c: (b, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, G, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, G, P, N), lambda b, c: (b, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * HG, S, G, P), x.dtype),
            jax.ShapeDtypeStruct((B * HG, G, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((G, P, N), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(xg, dtg, Ag, Bg, Cg)

    y = y.reshape(B, HG, S, G, P).transpose(0, 2, 1, 3, 4).reshape(B, S, H, P)
    h = h_final.reshape(B, HG, G, P, N).reshape(B, H, P, N)
    return y, h
