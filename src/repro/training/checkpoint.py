"""Minimal dependency-free checkpointing: pytree <-> .npz."""
from __future__ import annotations

import os
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_pytree(path: str, tree: Any) -> None:
    leaves, treedef = _flatten(tree)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    arrays = {}
    for i, x in enumerate(leaves):
        a = np.asarray(x)
        if a.dtype == jnp.bfloat16:  # numpy .npz cannot round-trip bf16
            a = a.astype(np.float32)
        arrays[f"leaf_{i}"] = a
    arrays["__treedef__"] = np.frombuffer(
        repr(treedef).encode(), dtype=np.uint8)
    np.savez(path, **arrays)


def load_pytree(path: str, like: Any) -> Any:
    """Load leaves into the structure of ``like`` (shapes must match)."""
    data = np.load(path, allow_pickle=False)
    leaves, treedef = _flatten(like)
    out = []
    for i, ref in enumerate(leaves):
        arr = data[f"leaf_{i}"]
        assert arr.shape == tuple(ref.shape), (
            f"checkpoint leaf {i}: {arr.shape} != {ref.shape}")
        out.append(jnp.asarray(arr, dtype=ref.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)
