"""LM training loop (used by the quickstart example and the end-to-end
driver that trains the tiny reasoning model the serving benchmarks sample
from)."""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.init import init_params
from repro.models.model import lm_loss
from repro.training.optimizer import AdamW, AdamState, cosine_schedule


@dataclasses.dataclass
class TrainConfig:
    steps: int = 300
    seq_len: int = 256
    batch_size: int = 16
    peak_lr: float = 3e-3
    warmup: int = 30
    weight_decay: float = 0.01
    log_every: int = 25
    seed: int = 0


def make_train_step(cfg: ModelConfig, opt: AdamW,
                    use_kernel: bool = False) -> Callable:
    @partial(jax.jit, donate_argnums=(0, 1))
    def train_step(params, opt_state, tokens, labels):
        loss, grads = jax.value_and_grad(
            lambda p: lm_loss(p, cfg, tokens, labels,
                              use_kernel=use_kernel))(params)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss
    return train_step


def train_lm(cfg: ModelConfig, tcfg: Optional[TrainConfig] = None,
             batches=None, verbose: bool = True):
    """Train from scratch on the synthetic reasoning task; returns
    (params, history)."""
    from repro.data.dataset import lm_batches
    tcfg = tcfg or TrainConfig()
    params = init_params(cfg, jax.random.PRNGKey(tcfg.seed))
    opt = AdamW(learning_rate=cosine_schedule(
        tcfg.peak_lr, tcfg.warmup, tcfg.steps),
        weight_decay=tcfg.weight_decay)
    opt_state = opt.init(params)
    step_fn = make_train_step(cfg, opt)
    if batches is None:
        batches = lm_batches(tcfg.seq_len, tcfg.batch_size, seed=tcfg.seed)

    history = []
    t0 = time.time()
    for step in range(tcfg.steps):
        arr = next(batches)
        tokens = jnp.asarray(arr[:, :-1])
        labels = jnp.asarray(arr[:, 1:])
        params, opt_state, loss = step_fn(params, opt_state, tokens, labels)
        if step % tcfg.log_every == 0 or step == tcfg.steps - 1:
            loss_f = float(loss)
            history.append({"step": step, "loss": loss_f,
                            "elapsed_s": time.time() - t0})
            if verbose:
                print(f"  train step {step:4d}  loss {loss_f:.4f}")
    return params, history
