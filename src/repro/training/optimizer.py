"""Optimizers (pure JAX, optax-free): Adam / AdamW + schedules.

States are pytrees mirroring the params tree; sharding rules therefore
apply to optimizer state exactly as to params (ZeRO-style sharding is a
launcher-level decision).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


@dataclasses.dataclass(frozen=True)
class AdamW:
    learning_rate: float | Callable[[jax.Array], jax.Array] = 1e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: Optional[float] = 1.0
    # Low-precision moment storage (production lever for models whose
    # fp32 Adam states exceed the pod's HBM, e.g. deepseek-v2-236b on
    # 256 v5e chips: 2.36 TB at fp32). Math stays fp32; only storage
    # rounds.
    moment_dtype: str = "float32"

    def init(self, params) -> AdamState:
        md = jnp.dtype(self.moment_dtype)
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, md), params)
        return AdamState(step=jnp.zeros((), jnp.int32), mu=zeros,
                         nu=jax.tree.map(jnp.copy, zeros))

    def _lr(self, step):
        if callable(self.learning_rate):
            return self.learning_rate(step)
        return self.learning_rate

    def update(self, grads, state: AdamState, params):
        step = state.step + 1
        if self.grad_clip is not None:
            gnorm = jnp.sqrt(sum(
                jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree.leaves(grads)))
            scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        md = jnp.dtype(self.moment_dtype)
        mu = jax.tree.map(
            lambda m, g: (self.b1 * m.astype(jnp.float32)
                          + (1 - self.b1) * g.astype(jnp.float32)).astype(md),
            state.mu, grads)
        nu = jax.tree.map(
            lambda v, g: (self.b2 * v.astype(jnp.float32)
                          + (1 - self.b2)
                          * jnp.square(g.astype(jnp.float32))).astype(md),
            state.nu, grads)
        lr = self._lr(step)
        b1c = 1 - self.b1 ** step.astype(jnp.float32)
        b2c = 1 - self.b2 ** step.astype(jnp.float32)

        def upd(p, m, v):
            u = (m.astype(jnp.float32) / b1c) \
                / (jnp.sqrt(v.astype(jnp.float32) / b2c) + self.eps)
            if self.weight_decay:
                u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, AdamState(step=step, mu=mu, nu=nu)


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor: float = 0.1):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5
                         * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return fn
